"""Sort-free combining-RMW engine: backend registry + model-driven dispatch.

The paper's fix for serialized atomics is software combining (§6.2.3); the
repo's original realization (`core.rmw.rmw_combining`) pays a stable
``argsort`` + segmented scan per batch — O(n log n) sort-dominated work that
TPUs execute poorly.  This module turns RMW execution into a pluggable
**backend engine**:

``serialized``
    The order-faithful ``lax.scan`` oracle (`core.rmw.rmw_serialized`) — the
    paper's measured hardware, and the only backend for general per-op
    expected CAS (the un-combinable "wasted work" case).
``sort``
    The existing argsort + segmented-scan combiner (`core.rmw.rmw_combining`)
    — the general-purpose path, still best for huge tables with tiny batches.
``onehot``
    NEW, sort-free: processes the batch in blocks, carrying the table between
    blocks.  Within a block, *fetched values* come from a strict-lower-
    triangular same-key contraction (an MXU-shaped (B,B) @ (B,) matmul) plus
    a gather of the carried table; table updates are plain bincount-style
    scatters.  O(n·B) contraction work instead of O(n log n) sort — no
    argsort anywhere.
``pallas``
    The Mosaic one-hot-matmul kernel (`kernels.rmw.ops.rmw_apply_fetched`);
    table tiles stay VMEM-resident across the index-block grid axis.  fp32
    tables only.

Every backend produces results bit-identical to ``rmw_serialized`` for every
op it supports (integer dtypes; float FAA is exact up to reassociation, the
same caveat the sort backend always had).  CAS is supported in combinable
form for a *uniform* expected value; per-op expected arrays fall back to the
oracle.

Selection (`select_backend`) is the paper's L(A, S) model used as an actual
runtime decision procedure: each backend exposes a predicted cost built from
:class:`repro.core.perf_model.HardwareSpec` constants (op, batch size, table
size -> seconds), and the cheapest *correct* backend wins.  ``execute_backend``
is the canonical entry, reached through the unified front-end
`repro.atomics.execute` (the PR-3 ``rmw_execute`` / ``arrival_rank`` shims
served their one-release window and are deleted).  The constants were tuned
from the committed ``benchmarks/results/rmw_backends.json`` sweep (see
README "RMW engine").
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import perf_model
from repro.core.placement import PlacementState, Tier
from repro.core.rmw import (OPS, RmwResult, _identity, rmw_combining,
                            rmw_serialized)

Array = jax.Array

#: default batch-block edge for the blocked one-hot backend (B x B same-key
#: contraction per block; 128 balances the O(B^2) intra-block traffic against
#: the per-block table-carry cost — see benchmarks/results/rmw_backends.json)
DEFAULT_ONEHOT_BLOCK = 128


def _is_uniform_expected(expected) -> bool:
    """True when CAS `expected` is one shared value (combinable form)."""
    if expected is None:
        return False
    return jnp.ndim(expected) == 0


# ---------------------------------------------------------------------------
# The sort-free one-hot backend
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("op", "block", "need_fetched"))
def rmw_onehot(table: Array, indices: Array, values: Array, op: str,
               expected: Optional[Array] = None, *,
               block: int = DEFAULT_ONEHOT_BLOCK,
               need_fetched: bool = True) -> RmwResult:
    """Serialized-equivalent RMW batch with **no argsort**.

    The batch is cut into blocks of ``block`` ops.  A ``lax.scan`` carries the
    table (plus one scratch row for dropped/padding ops) across blocks; within
    a block the exclusive per-slot prefix each op observes is

        prefix[i] = combine_{j<i, idx[j]==idx[i]} values[j]

    computed from the strict-lower-triangular same-key mask — for FAA that is
    exactly the lower-triangular-masked one-hot matmul ``(L ∘ same) @ v``.
    ``fetched[i] = combine(table_carry[idx[i]], prefix[i])``.

    ``need_fetched=False`` skips the prefix machinery entirely and computes
    the final table in one bincount-style scatter pass (O(n + m), no blocks,
    no carry) — the right mode for table-only callers (gradient scatter,
    histograms, BFS CAS parents).  The returned ``fetched``/``success`` are
    then all-zeros placeholders; only ``.table`` is meaningful.

    Indices outside [0, table size) are routed to the scratch row (dropped),
    matching the Pallas kernel's masking convention; their fetched/success
    outputs are meaningless.
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}")
    if op == "cas" and expected is None:
        raise ValueError("cas requires `expected`")
    if not need_fetched:
        return _tables_only(table, indices, values, op, expected)

    n = indices.shape[0]
    m = table.shape[0]
    b = int(min(block, max(8, n)))
    pad = (-n) % b
    nb = (n + pad) // b

    idx = indices.astype(jnp.int32)
    idx = jnp.where((idx < 0) | (idx > m), m, idx)       # m == scratch row
    idx = jnp.concatenate([idx, jnp.full((pad,), m, jnp.int32)])
    val = jnp.concatenate([values, jnp.zeros((pad,), values.dtype)])
    acc0 = jnp.concatenate([table, table[:1]])           # scratch row at m

    pos = jnp.arange(b, dtype=jnp.int32)
    tri = pos[:, None] > pos[None, :]                    # strict lower (B,B)
    exp = None if expected is None else jnp.asarray(expected, table.dtype)

    def step(acc, xs):
        ib, vb = xs                                       # (B,), (B,)
        same = (ib[:, None] == ib[None, :]) & tri         # j < i, same slot
        base = acc[ib]                                    # carried table value

        if op == "faa":
            prefix = same.astype(vb.dtype) @ vb           # tri-masked matmul
            fetched = base + prefix
            ok = jnp.ones((b,), bool)
            acc = acc.at[ib].add(vb)
        elif op in ("min", "max"):
            ident = _identity(op, vb.dtype)
            comb = jnp.minimum if op == "min" else jnp.maximum
            masked = jnp.where(same, vb[None, :], ident)
            prefix = (jnp.min(masked, axis=1) if op == "min"
                      else jnp.max(masked, axis=1))
            fetched = comb(base, prefix)
            ok = jnp.ones((b,), bool)
            acc = acc.at[ib].min(vb) if op == "min" else acc.at[ib].max(vb)
        elif op == "swp":
            mpos = jnp.where(same, pos[None, :], -1).max(axis=1)
            prev = vb[jnp.clip(mpos, 0)]
            fetched = jnp.where(mpos >= 0, prev, base)
            ok = jnp.ones((b,), bool)
            # last collider per slot wins; earlier ones go to the scratch row
            later_same = (ib[:, None] == ib[None, :]) \
                & (pos[:, None] < pos[None, :])
            is_last = ~later_same.any(axis=1)
            acc = acc.at[jnp.where(is_last, ib, m)].set(vb)
        else:  # cas, uniform expected
            # Serialized CAS chains compose associatively: the slot's value
            # after a collider group is `first value != expected` (writes of
            # the expected value keep the chain alive).  See core.rmw.
            ne = vb != exp
            fpos = jnp.where(same & ne[None, :], pos[None, :], b).min(axis=1)
            x_excl = jnp.where(fpos < b, vb[jnp.clip(fpos, 0, b - 1)], exp)
            v_before = jnp.where(base == exp, x_excl, base)
            fetched = v_before
            ok = v_before == exp
            # block winner = first op with value != expected at a live slot
            is_first_ne = ne & (fpos == b)
            write = is_first_ne & (base == exp)
            acc = acc.at[jnp.where(write, ib, m)].set(vb)
        return acc, (fetched, ok)

    acc, (fetched, ok) = jax.lax.scan(
        step, acc0, (idx.reshape(nb, b), val.reshape(nb, b)))
    return RmwResult(acc[:m], fetched.reshape(-1)[:n], ok.reshape(-1)[:n])


def _tables_only(table: Array, indices: Array, values: Array, op: str,
                 expected: Optional[Array]) -> RmwResult:
    """Final table in one scatter pass (the sort-free 'bincount' core).

    Out-of-range-high indices drop via XLA's native scatter semantics (the
    same convention the sort backend's scatters use); negative indices are
    remapped past the table so they drop too instead of wrapping
    NumPy-style — matching the fetched path on identical inputs.
    """
    n = indices.shape[0]
    m = table.shape[0]
    idx = indices.astype(jnp.int32)
    idx = jnp.where(idx < 0, jnp.int32(m), idx)
    pos = jnp.arange(n, dtype=jnp.int32)
    if op == "faa":
        tab = table.at[idx].add(values)
    elif op in ("min", "max"):
        tab = (table.at[idx].min(values) if op == "min"
               else table.at[idx].max(values))
    elif op == "swp":
        last = jnp.full((m,), -1, jnp.int32).at[idx].max(pos)
        tab = jnp.where(last >= 0, values[jnp.clip(last, 0)], table)
    else:  # cas, uniform expected: slot = first value != expected if live
        e = jnp.asarray(expected, table.dtype)
        first = jnp.full((m,), n, jnp.int32).at[idx].min(
            jnp.where(values != e, pos, n))
        tab = jnp.where((table == e) & (first < n),
                        values[jnp.clip(first, 0, n - 1)], table)
    return RmwResult(tab, jnp.zeros((n,), values.dtype),
                     jnp.zeros((n,), bool))


def slot_occupancy(indices: Array, m: int) -> Array:
    """(m,) int32 per-slot writer counts for a batch of slot indices.

    This *is* the onehot backend's bincount pass (`_tables_only` FAA with
    unit values) exposed for the contention observatory (PR 10) instead of
    recomputed: out-of-range-high indices drop, negatives are remapped past
    the table so they drop too — exactly the occupancy the combine passes
    act on.  Pure jnp; traces inside jit/shard_map.
    """
    ones = jnp.ones(indices.shape, jnp.int32)
    return _tables_only(jnp.zeros((m,), jnp.int32), indices, ones,
                        "faa", None).table


@partial(jax.jit, static_argnames=("num_keys", "block"))
def _arrival_rank_sortfree(keys: Array, num_keys: int, *,
                           block: int = DEFAULT_ONEHOT_BLOCK) -> Array:
    """Sort-free per-element arrival order among equal keys (0-based).

    The FAA-fetch identity: rank[i] = fetched value of FAA(counter[key], 1)
    executed in element order.  For small key spaces a dense one-hot cumsum
    (one associative scan, MXU/VPU friendly) wins; for large ones the blocked
    one-hot backend computes the same thing without materializing (n, K).
    Public spelling: `repro.atomics.arrival_rank` (this module's old
    `arrival_rank` shim around this function is deleted).
    """
    n = keys.shape[0]
    k = jnp.asarray(keys, jnp.int32)
    if n * num_keys <= (1 << 22):
        onehot = (k[:, None] == jnp.arange(num_keys, dtype=jnp.int32)[None, :])
        incl = jnp.cumsum(onehot.astype(jnp.int32), axis=0)
        return jnp.take_along_axis(incl, k[:, None], axis=1)[:, 0] - 1
    res = rmw_onehot(jnp.zeros((num_keys,), jnp.int32), k,
                     jnp.ones((n,), jnp.int32), "faa", block=block)
    return res.fetched


# ---------------------------------------------------------------------------
# Predicted-cost models (the paper's L(A,S) as a decision procedure)
# ---------------------------------------------------------------------------

def _op_for_model(op: str) -> str:
    # perf_model's RMW_OPS has no min/max; they execute like FAA (one
    # combine ALU op on the fetched line).
    return op if op in perf_model.RMW_OPS else "faa"


def _table_tier(nbytes: int) -> Tier:
    """Working tier of the table: on-chip while it fits, HBM/DRAM beyond."""
    return Tier.VMEM if nbytes <= (1 << 21) else Tier.HBM_LOCAL


def _table_state(m: int, itemsize: int = 4) -> PlacementState:
    return PlacementState(tier=_table_tier(m * itemsize))


def cost_serialized(spec: perf_model.HardwareSpec, op: str, n: int, m: int,
                    need_fetched: bool = True) -> float:
    """n dependent atomics, each paying the paper's full L(A, S).

    The software oracle additionally pays one scan step per op (hardware
    atomics would not), so the same `loop_step_s` constant applies per op.
    """
    per_op = perf_model.latency(spec, _op_for_model(op), _table_state(m))
    return n * (per_op + (spec.loop_step_s or 1e-6))


def cost_sort(spec: perf_model.HardwareSpec, op: str, n: int, m: int,
              need_fetched: bool = True) -> float:
    """argsort (log2 n passes) + log-depth segmented scan + gather/scatter."""
    sort_pass = spec.sort_elem_pass_s or 8.0 / max(spec.combine_ops_per_s, 1.0)
    gather = spec.gather_elem_s or sort_pass / 2
    passes = max(1.0, math.log2(max(n, 2)))
    scan = max(1.0, math.log2(max(n, 2))) / max(spec.combine_ops_per_s, 1.0)
    return n * passes * sort_pass + n * scan + 4 * n * gather


def cost_onehot(spec: perf_model.HardwareSpec, op: str, n: int, m: int,
                need_fetched: bool = True,
                block: int = DEFAULT_ONEHOT_BLOCK) -> float:
    """Blocked: ceil(n/B) x (B^2 contraction + table carry); scatter-only
    (O(n + m) bincount) when fetched values aren't needed."""
    gather = spec.gather_elem_s or 2e-9
    if not need_fetched:
        return (n + m) * gather
    b = min(block, max(8, n))
    blocks = -(-n // b)
    step = spec.loop_step_s or 1e-6
    mac = 2.0 * b * b / max(spec.peak_flops, 1.0)
    # each scan step re-materializes the carried table (copy traffic), and
    # gathers degrade once the table spills the on-chip tier
    carry = 4.0 * m / max(spec.tier_bandwidth_Bps[_table_tier(4 * m)], 1.0)
    tier_pen = 1.0 if _table_tier(4 * m) is Tier.VMEM else 2.0
    return blocks * (mac + step + carry) + 3.0 * n * gather * tier_pen


def cost_pallas(spec: perf_model.HardwareSpec, op: str, n: int, m: int,
                need_fetched: bool = True) -> float:
    """One-hot contraction over every (table-tile, index-block) pair."""
    if jax.default_backend() != "tpu":
        # interpret mode: each grid step is Python-dispatched jnp — only ever
        # competitive in this container for validation, never for speed.
        return 1e-3 * max(1, (m // 512)) * max(1, (n // 1024)) + 1e-2
    return (2.0 * n * m / max(spec.peak_flops, 1.0)
            + (4.0 * (n + m)) / max(spec.hbm_Bps, 1.0))


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RmwBackend:
    """One way of executing an RMW batch, plus its predicted-cost model."""

    name: str
    ops: frozenset                      # ops with serialized-equivalent results
    run: Callable[..., RmwResult]       # (table, indices, values, op,
                                        #  expected, need_fetched=...)
    cost: Callable[..., float]          # (spec, op, n, m, need_fetched)
    general_cas: bool = False           # per-op expected arrays supported?
    float_table_only: bool = False      # e.g. the fp32 Pallas kernel

    def supports(self, op: str, *, uniform_expected: bool = True,
                 dtype=None) -> bool:
        if op not in self.ops:
            return False
        if op == "cas" and not uniform_expected and not self.general_cas:
            return False
        if self.float_table_only and dtype is not None \
                and not jnp.issubdtype(dtype, jnp.floating):
            return False
        return True


def _run_pallas(table, indices, values, op, expected=None,
                need_fetched=True):
    from repro.kernels.rmw import ops as kops   # deferred: keeps core import-light
    if not need_fetched and op != "cas":
        out = kops.rmw_apply(table, indices, values, op)
        return RmwResult(out, jnp.zeros(indices.shape, table.dtype),
                         jnp.zeros(indices.shape, bool))
    return kops.rmw_apply_fetched(table, indices, values, op,
                                  expected=expected)


BACKENDS: Dict[str, RmwBackend] = {}


def register_backend(backend: RmwBackend) -> None:
    BACKENDS[backend.name] = backend


register_backend(RmwBackend(
    name="serialized", ops=frozenset(OPS),
    run=lambda t, i, v, op, e=None, need_fetched=True:
        rmw_serialized(t, i, v, op, e),
    cost=cost_serialized, general_cas=True))
register_backend(RmwBackend(
    name="sort", ops=frozenset(OPS),
    run=lambda t, i, v, op, e=None, need_fetched=True:
        rmw_combining(t, i, v, op, e),
    cost=cost_sort))
register_backend(RmwBackend(
    name="onehot", ops=frozenset(OPS),
    run=lambda t, i, v, op, e=None, need_fetched=True:
        rmw_onehot(t, i, v, op, e, need_fetched=need_fetched),
    cost=cost_onehot))
register_backend(RmwBackend(
    name="pallas", ops=frozenset(("faa", "min", "max", "swp", "cas")),
    run=_run_pallas, cost=cost_pallas, float_table_only=True))


def calibrated_spec_path() -> str:
    """Where `benchmarks/calibrate.py` persists the fitted CPU spec.

    Overridable via ``REPRO_CALIBRATED_SPEC`` (tests use this); the default
    is the committed benchmark-results location at the repo root.
    """
    import os
    env = os.environ.get("REPRO_CALIBRATED_SPEC")
    if env:
        return env
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, "benchmarks", "results",
                        "calibrated_spec.json")


_SPEC_CACHE: Dict[str, perf_model.HardwareSpec] = {}

# Process-wide "live spec" override (`repro.tuning.SpecController` installs
# its tuned spec here).  All three selector tiers default their spec through
# `default_spec()`, so this single indirection swaps the active cost model
# everywhere at once.  The epoch counter is bumped on every swap; decision
# caches keyed on it (atomics.execute, atomics.retry) invalidate themselves
# the moment a new spec lands.  The spec only ever steers *selection* —
# every backend/strategy is bit-identical to the serialized oracle — so a
# live swap can never change results, only which implementation runs.
_LIVE_SPEC: Optional[perf_model.HardwareSpec] = None
_SPEC_EPOCH: int = 0


def _reset_spec_cache() -> None:  # test hook
    _SPEC_CACHE.clear()


def set_live_spec(spec: perf_model.HardwareSpec) -> int:
    """Install ``spec`` as the process-wide selection cost model and return
    the new spec epoch.  Takes effect for every subsequent `default_spec()`
    call across all tiers; previously jitted/cached computations keep the
    selection they were traced with (documented staleness — re-tracing picks
    up the new spec)."""
    global _LIVE_SPEC, _SPEC_EPOCH
    if not isinstance(spec, perf_model.HardwareSpec):
        raise TypeError(f"live spec must be a HardwareSpec, got {type(spec)}")
    _LIVE_SPEC = spec
    _SPEC_EPOCH += 1
    return _SPEC_EPOCH


def clear_live_spec() -> None:
    """Drop the live override; `default_spec()` reverts to the calibrated
    platform spec.  Bumps the epoch so decision caches refresh."""
    global _LIVE_SPEC, _SPEC_EPOCH
    if _LIVE_SPEC is not None:
        _LIVE_SPEC = None
        _SPEC_EPOCH += 1


def live_spec() -> Optional[perf_model.HardwareSpec]:
    """The installed live override, or None when untuned."""
    return _LIVE_SPEC


def spec_epoch() -> int:
    """Monotonic counter bumped on every live-spec install/clear.  Decision
    caches include it in their keys so spec swaps invalidate stale entries."""
    return _SPEC_EPOCH


def calibrated_spec() -> perf_model.HardwareSpec:
    """Platform spec ignoring any live-tuned override: TPU constants on TPU;
    on CPU the calibrated spec from `benchmarks/calibrate.py` when present
    (falling back to the priors).  This is the envelope anchor the tuning
    controller validates live proposals against."""
    backend = jax.default_backend()
    if backend in _SPEC_CACHE:
        return _SPEC_CACHE[backend]
    if backend == "tpu":
        spec = perf_model.TPU_V5E
    else:
        spec = perf_model.cpu_default_spec()
        path = calibrated_spec_path()
        try:
            import json
            import os
            if os.path.exists(path):
                with open(path) as f:
                    payload = json.load(f)
                if payload.get("jax_backend", backend) == backend:
                    spec = perf_model.spec_from_dict(
                        payload.get("spec", payload), base=spec)
        except (OSError, ValueError, KeyError, TypeError):
            pass  # unreadable calibration files must never break dispatch
    _SPEC_CACHE[backend] = spec
    return spec


def default_spec() -> perf_model.HardwareSpec:
    """The spec every selector tier uses when the caller passes none: the
    live-tuned override when a `repro.tuning.SpecController` has installed
    one, else the calibrated platform spec."""
    if _LIVE_SPEC is not None:
        return _LIVE_SPEC
    return calibrated_spec()


class Selection(NamedTuple):
    """A selector decision plus its predicted-cost record — what the
    telemetry layer persists so predicted-vs-measured drift can be
    tracked per tier (`repro.telemetry.drift`)."""

    choice: str                  # winning backend/strategy name
    predicted_s: float           # its predicted cost (the model's claim)
    costs: Dict[str, float]      # every candidate's prediction


def select_backend_with_cost(op: str, n: int, m: int,
                             spec: Optional[perf_model.HardwareSpec] = None,
                             *, uniform_expected: bool = True, dtype=None,
                             need_fetched: bool = True) -> Selection:
    """`select_backend` returning the full predicted-cost record."""
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}")
    spec = spec or default_spec()
    costs = {b.name: b.cost(spec, op, n, m, need_fetched)
             for b in BACKENDS.values()
             if b.supports(op, uniform_expected=uniform_expected,
                           dtype=dtype)}
    choice = min(costs, key=costs.get)
    return Selection(choice, costs[choice], costs)


def select_backend(op: str, n: int, m: int,
                   spec: Optional[perf_model.HardwareSpec] = None, *,
                   uniform_expected: bool = True, dtype=None,
                   need_fetched: bool = True) -> str:
    """Cheapest backend whose semantics cover (op, expected-mode, dtype)."""
    return select_backend_with_cost(
        op, n, m, spec, uniform_expected=uniform_expected, dtype=dtype,
        need_fetched=need_fetched).choice


def execute_backend(table: Array, indices: Array, values: Array, op: str,
                    expected: Optional[Array] = None, *,
                    backend: str = "auto",
                    spec: Optional[perf_model.HardwareSpec] = None,
                    need_fetched: bool = True) -> RmwResult:
    """Run an RMW batch on the named backend ("auto" = cost-model pick).

    The local tier of the unified front-end — call it through
    `repro.atomics.execute`; this raw-array spelling is the internal entry
    the sharded subsystem's pre-combine/resolve passes use.

    Shapes are static under jit, so auto-selection happens at trace time and
    costs nothing at runtime.  All backends return the serialized-equivalent
    :class:`~repro.core.rmw.RmwResult`.

    ``need_fetched=False`` declares that the caller consumes only ``.table``
    (for CAS, also not ``.success``): backends may then skip the per-op
    fetch-result machinery (the one-hot backend degenerates to a single
    bincount-style scatter pass) and the returned fetched/success fields are
    unspecified.
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}")
    if op == "cas" and expected is None:
        raise ValueError("cas requires `expected`")
    if backend == "auto":
        backend = select_backend(
            op, int(indices.shape[0]), int(table.shape[0]), spec,
            uniform_expected=(op != "cas") or _is_uniform_expected(expected),
            dtype=table.dtype, need_fetched=need_fetched)
    try:
        b = BACKENDS[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"have {sorted(BACKENDS)}") from None
    if op == "cas" and not b.general_cas \
            and not _is_uniform_expected(expected):
        raise ValueError(
            f"backend {b.name!r} supports CAS only with a scalar (uniform) "
            f"`expected`; per-op expected arrays need the serialized oracle")
    return b.run(table, indices, values, op, expected,
                 need_fetched=need_fetched)
