"""Core: the paper's contribution as a composable JAX module.

Layers (DESIGN.md §2-3):
  placement        — PlacementState: the TPU analogue of coherency states
  perf_model       — L(A,S) = R_O(S) + E(A) + O, bandwidth, ILP-gap, calibration
  contention       — §5.4 contention model (serialized ping-pong vs combining)
  collective_model — mesh collectives priced from per-hop R_O terms
  rmw              — vectorized CAS/FAA/SWP with serialized-equivalent semantics
  rmw_engine       — backend registry (sort / sort-free one-hot / Pallas /
                     oracle) + cost-model-driven auto-selection
  rmw_sharded      — mesh-wide sharded atomics: two-phase combine/resolve
                     over shard_map axes with hierarchical (per-pod) trees
  validation       — the paper's NRMSE gate (Eq. 12)
  planner          — model-driven schedule/capacity decisions

Note: `from repro.core import rmw` yields the *module*; the batch-RMW facade
function it defines is re-exported as `rmw_run` (the old function-shadowing
re-export was a namespace collision — the module stays callable with a
DeprecationWarning for legacy callers).
"""

from repro.core.placement import Ownership, PlacementState, Tier  # noqa: F401
from repro.core.perf_model import (  # noqa: F401
    RMW_OPS, TPU_V5E, HardwareSpec, bandwidth, calibrate, cpu_default_spec,
    ilp_gap, latency, read_for_ownership, read_latency, relaxed_bandwidth,
    spec_from_dict, spec_to_dict, unaligned_latency)
from repro.core.rmw import (  # noqa: F401
    OPS, RmwConfig, RmwResult, arrival_rank, rmw_combining, rmw_serialized,
    scatter_add_grads, segmented_scan)
from repro.core.rmw import rmw as rmw_run  # noqa: F401  (renamed re-export)
from repro.core.rmw_engine import (  # noqa: F401
    BACKENDS, RmwBackend, calibrated_spec_path, default_spec,
    register_backend, rmw_execute, rmw_onehot, select_backend)
from repro.core.rmw_sharded import (  # noqa: F401
    EXCHANGE_COSTS, STRATEGIES, MeshAxis, cost_exchange_hierarchical,
    cost_exchange_oneshot, rmw_sharded, select_exchange)
from repro.core.validation import NRMSE_GATE, ValidationRow, nrmse, validate  # noqa: F401

# re-bind the submodule under its own name (the collision fix): the
# `from repro.core.rmw import ...` lines above imported the submodule, so it
# is in sys.modules; this import statement makes the package attribute the
# MODULE rather than whatever was re-exported last.
from repro.core import rmw  # noqa: F401, E402
