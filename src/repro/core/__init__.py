"""Core: the paper's contribution as a composable JAX module.

Layers (DESIGN.md §2-3):
  placement        — PlacementState: the TPU analogue of coherency states
  perf_model       — L(A,S) = R_O(S) + E(A) + O, bandwidth, ILP-gap, calibration
  contention       — §5.4 contention model (serialized ping-pong vs combining)
  collective_model — mesh collectives priced from per-hop R_O terms
  rmw              — vectorized CAS/FAA/SWP with serialized-equivalent semantics
  rmw_engine       — backend registry (sort / sort-free one-hot / Pallas /
                     oracle) + cost-model-driven auto-selection
  rmw_sharded      — mesh-wide sharded atomics: two-phase combine/resolve
                     over shard_map axes with hierarchical (per-pod) trees
  validation       — the paper's NRMSE gate (Eq. 12)
  planner          — model-driven schedule/capacity decisions

The one public way to *issue* RMW batches is the typed front-end
`repro.atomics` (`execute`, `Faa`/`Swp`/`Min`/`Max`/`Cas`, `AtomicTable`,
`arrival_rank`).  The old per-tier entry points re-exported here —
``rmw_run``, ``rmw_execute``, ``rmw_sharded``, both ``arrival_rank``
spellings — are deprecation shims that warn and forward; the raw-array
internal entries are ``rmw_engine.execute_backend`` and
``rmw_sharded.execute_sharded``.
"""

from repro.core.placement import Ownership, PlacementState, Tier  # noqa: F401
from repro.core.perf_model import (  # noqa: F401
    RMW_OPS, TPU_V5E, HardwareSpec, bandwidth, calibrate, cpu_default_spec,
    ilp_gap, latency, read_for_ownership, read_latency, relaxed_bandwidth,
    spec_from_dict, spec_to_dict, unaligned_latency)
from repro.core.rmw import (  # noqa: F401
    OPS, RmwConfig, RmwResult, arrival_rank, rmw_combining, rmw_serialized,
    scatter_add_grads, segmented_scan)
from repro.core.rmw import rmw as rmw_run  # noqa: F401  (deprecated shim)
from repro.core.rmw_engine import (  # noqa: F401
    BACKENDS, RmwBackend, calibrated_spec_path, default_spec,
    execute_backend, register_backend, rmw_execute, rmw_onehot,
    select_backend)
from repro.core.rmw_sharded import (  # noqa: F401
    EXCHANGE_COSTS, STRATEGIES, MeshAxis, cost_exchange_hierarchical,
    cost_exchange_oneshot, execute_sharded, rmw_sharded, select_exchange)
from repro.core.validation import NRMSE_GATE, ValidationRow, nrmse, validate  # noqa: F401

# Namespace contract during the deprecation window:
#   * `repro.core.rmw` is the MODULE (PR 2's collision fix — the facade
#     function is re-exported as `rmw_run`, now a warning shim);
#   * `repro.core.rmw_sharded` stays the deprecated FUNCTION, exactly what
#     PR 2 shipped, so existing `from repro.core import rmw_sharded`
#     callers get the one-release DeprecationWarning instead of a
#     "'module' object is not callable" hard break.  The module is always
#     reachable by its full path (`from repro.core.rmw_sharded import ...`).
# Both disappear with the shims one release after PR 3.
import sys as _sys  # noqa: E402

rmw = _sys.modules["repro.core.rmw"]
del _sys
