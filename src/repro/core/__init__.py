"""Core: the paper's contribution as a composable JAX module.

Layers (DESIGN.md §2-3):
  placement        — PlacementState: the TPU analogue of coherency states
  perf_model       — L(A,S) = R_O(S) + E(A) + O, bandwidth, ILP-gap, calibration
  contention       — §5.4 contention model (serialized ping-pong vs combining)
  collective_model — mesh collectives priced from per-hop R_O terms
  rmw              — vectorized CAS/FAA/SWP with serialized-equivalent semantics
  rmw_engine       — backend registry (sort / sort-free one-hot / Pallas /
                     oracle) + cost-model-driven auto-selection
  rmw_sharded      — mesh-wide sharded atomics: two-phase combine/resolve
                     over shard_map axes with hierarchical (per-pod) trees
  validation       — the paper's NRMSE gate (Eq. 12)
  planner          — model-driven schedule/capacity decisions

The one public way to *issue* RMW batches is the typed front-end
`repro.atomics` (`execute`, `Faa`/`Swp`/`Min`/`Max`/`Cas`, `AtomicTable`,
`arrival_rank`).  The PR-3 deprecation shims (``rmw_run``, ``rmw_execute``,
``rmw_sharded``, both old ``arrival_rank`` spellings) completed their
one-release window and were deleted; ``repro.core.rmw`` and
``repro.core.rmw_sharded`` are now plainly the modules, and the raw-array
internal entries are ``rmw_engine.execute_backend`` and
``rmw_sharded.execute_sharded``.
"""

from repro.core.placement import Ownership, PlacementState, Tier  # noqa: F401
from repro.core.perf_model import (  # noqa: F401
    RMW_OPS, TPU_V5E, HardwareSpec, bandwidth, calibrate, cpu_default_spec,
    ilp_gap, latency, read_for_ownership, read_latency, relaxed_bandwidth,
    spec_from_dict, spec_to_dict, unaligned_latency)
from repro.core.rmw import (  # noqa: F401
    OPS, RmwResult, rmw_combining, rmw_serialized, scatter_add_grads,
    segmented_scan)
from repro.core.rmw_engine import (  # noqa: F401
    BACKENDS, RmwBackend, calibrated_spec_path, default_spec,
    execute_backend, register_backend, rmw_onehot, select_backend)
from repro.core.rmw_sharded import (  # noqa: F401
    EXCHANGE_COSTS, STRATEGIES, MeshAxis, cost_exchange_hierarchical,
    cost_exchange_oneshot, execute_sharded, select_exchange)
from repro.core.validation import NRMSE_GATE, ValidationRow, nrmse, validate  # noqa: F401
