"""Core: the paper's contribution as a composable JAX module.

Layers (DESIGN.md §2-3):
  placement        — PlacementState: the TPU analogue of coherency states
  perf_model       — L(A,S) = R_O(S) + E(A) + O, bandwidth, ILP-gap, calibration
  contention       — §5.4 contention model (serialized ping-pong vs combining)
  collective_model — mesh collectives priced from per-hop R_O terms
  rmw              — vectorized CAS/FAA/SWP with serialized-equivalent semantics
  rmw_engine       — backend registry (sort / sort-free one-hot / Pallas /
                     oracle) + cost-model-driven auto-selection
  validation       — the paper's NRMSE gate (Eq. 12)
  planner          — model-driven schedule/capacity decisions
"""

from repro.core.placement import Ownership, PlacementState, Tier  # noqa: F401
from repro.core.perf_model import (  # noqa: F401
    RMW_OPS, TPU_V5E, HardwareSpec, bandwidth, calibrate, cpu_default_spec,
    ilp_gap, latency, read_for_ownership, read_latency, relaxed_bandwidth,
    unaligned_latency)
from repro.core.rmw import (  # noqa: F401
    OPS, RmwConfig, RmwResult, arrival_rank, rmw, rmw_combining,
    rmw_serialized, scatter_add_grads, segmented_scan)
from repro.core.rmw_engine import (  # noqa: F401
    BACKENDS, RmwBackend, register_backend, rmw_execute, rmw_onehot,
    select_backend)
from repro.core.validation import NRMSE_GATE, ValidationRow, nrmse, validate  # noqa: F401
