"""Vectorized RMW (read-modify-write) with the paper's atomic semantics.

The paper benchmarks CAS / FAA / SWP — hardware-serialized RMWs on cache
lines.  The TPU has no hardware atomics; instead, a *batch* of RMWs against a
table is executed as a data-parallel **combine-by-index** whose results are
bit-identical to executing the batch serially in order (the paper's hardware
semantics).  This module provides:

* :func:`rmw_serialized` — the order-faithful oracle (``lax.scan``, one op per
  step) — models the paper's measured hardware behaviour (no ILP, §5.2).
* :func:`rmw_combining`  — the vectorized segmented-scan implementation — the
  paper's *proposed* relaxed atomics (§6.2.3) which TPUs realize in software.
  For FAA/SWP/MIN/MAX and for CAS with a uniform expected value it returns
  exactly the serialized result (property-tested in tests/test_rmw.py).

Shared helpers (`segmented_scan`, the argsort arrival rank behind
`repro.atomics.arrival_rank`) are reused by the MoE dispatch
(position-in-expert counters = FAA fetch results) and the BFS example
(parent updates = CAS/SWP).

This module holds the *sort* (argsort + segmented scan) implementation and
the serialized oracle — implementation building blocks for the engine
(`core.rmw_engine`) and the unified front-end (`repro.atomics`, the one
public entry).  The PR-3 deprecation shims (the ``rmw()`` facade and the
argsort ``arrival_rank`` spelling) completed their one-release window and
are gone; `repro.atomics.execute` / `repro.atomics.arrival_rank` are the
public spellings.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

OPS = ("faa", "swp", "cas", "min", "max")


class RmwResult(NamedTuple):
    table: Array    # table after all ops applied
    fetched: Array  # per-op value observed *before* that op (serialized order)
    success: Array  # per-op bool; always True for non-CAS ops


# ---------------------------------------------------------------------------
# Segmented scan machinery (the classic (flag, value) monoid)
# ---------------------------------------------------------------------------

def segmented_scan(values: Array, seg_start: Array,
                   combine: Callable[[Array, Array], Array]) -> Array:
    """Inclusive segmented scan: scans ``values`` with ``combine`` but restarts
    at every True in ``seg_start``.  Associative, so it lowers to
    ``lax.associative_scan`` (log-depth — the 'relaxed atomics' fast path)."""

    def op(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb, vb, combine(va, vb))

    flags = seg_start.astype(bool)
    _, out = jax.lax.associative_scan(op, (flags, values))
    return out


def _exclusive_from_inclusive(incl: Array, values: Array, seg_start: Array,
                              identity) -> Array:
    """Shift an inclusive segmented scan to exclusive (identity at seg starts)."""
    shifted = jnp.roll(incl, 1, axis=0)
    first = jnp.zeros_like(seg_start).at[0].set(True) | seg_start
    return jnp.where(first, jnp.asarray(identity, incl.dtype), shifted)


def _sort_by_index(indices: Array, *arrays: Array):
    order = jnp.argsort(indices, stable=True)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    sorted_idx = indices[order]
    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_idx[1:] != sorted_idx[:-1]])
    return order, inv, sorted_idx, seg_start, tuple(a[order] for a in arrays)


def _arrival_rank_argsort(keys: Array) -> Array:
    """Per-element arrival order among equal keys (0-based), via argsort.

    Semantically this is the fetch result of FAA(counter[key], 1) executed in
    element order — the exact primitive MoE dispatch uses to assign each token
    its slot within its expert's capacity buffer.  The sort-free version
    lives in the engine; `repro.atomics.arrival_rank` is the one public
    spelling (this path is its ``num_keys=None`` fallback).
    """
    order, inv, _, seg_start, _ = _sort_by_index(keys)
    ones = jnp.ones_like(keys, dtype=jnp.int32)
    incl = segmented_scan(ones, seg_start, jnp.add)
    return (incl - 1)[inv]


# ---------------------------------------------------------------------------
# Serialized oracle (paper hardware: one atomic at a time)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("op",))
def rmw_serialized(table: Array, indices: Array, values: Array, op: str,
                   expected: Optional[Array] = None) -> RmwResult:
    """Apply ops one-at-a-time in order; the semantics oracle.

    This is also the performance model of the *paper's measured hardware*:
    fully serialized execution with zero ILP between atomics (§5.2).
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}")
    if op == "cas" and expected is None:
        raise ValueError("cas requires `expected`")
    exp = expected if expected is not None else jnp.zeros_like(values)

    def step(tab, inp):
        i, v, e = inp
        old = tab[i]
        if op == "faa":
            new, ok = old + v, jnp.array(True)
        elif op == "swp":
            new, ok = v, jnp.array(True)
        elif op == "min":
            new, ok = jnp.minimum(old, v), jnp.array(True)
        elif op == "max":
            new, ok = jnp.maximum(old, v), jnp.array(True)
        else:  # cas
            ok = old == e
            new = jnp.where(ok, v, old)
        return tab.at[i].set(new), (old, ok)

    table, (fetched, success) = jax.lax.scan(step, table, (indices, values, exp))
    return RmwResult(table, fetched, success)


# ---------------------------------------------------------------------------
# Combining implementation (the paper's proposed relaxed atomics, vectorized)
# ---------------------------------------------------------------------------

def _combine_fn(op: str):
    return {"faa": jnp.add, "min": jnp.minimum, "max": jnp.maximum}[op]


def _identity(op: str, dtype):
    if op == "faa":
        return jnp.zeros((), dtype)
    if op == "min":
        return jnp.array(jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer)
                         else jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer)
                     else -jnp.inf, dtype)


@partial(jax.jit, static_argnames=("op",))
def rmw_combining(table: Array, indices: Array, values: Array, op: str,
                  expected: Optional[Array] = None) -> RmwResult:
    """Vectorized RMW batch, serialized-equivalent results.

    FAA/MIN/MAX: fetched = table ⊕ (exclusive segmented scan of colliders);
    SWP: fetched = previous collider's value (or the table value for the first);
    CAS: supported for a *uniform* expected value (first-wins within a segment)
    — the BFS/dispatch pattern; general per-op expected falls back to the
    serialized oracle (the paper's 'wasted work' case cannot be combined).
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}")
    n = indices.shape[0]
    if op == "cas":
        if expected is None:
            raise ValueError("cas requires `expected`")
        # Uniform-expected CAS is combinable; otherwise use the oracle.
        return _cas_uniform(table, indices, values, expected)

    order, inv, idx_s, seg_start, (val_s,) = _sort_by_index(indices, values)
    base = table[idx_s]

    if op == "swp":
        prev = jnp.roll(val_s, 1, axis=0)
        fetched_s = jnp.where(seg_start, base, prev)
        # last-wins: route non-final writes to a scratch row
        is_end = jnp.concatenate([seg_start[1:], jnp.ones((1,), bool)])
        scratch = jnp.asarray(table.shape[0], idx_s.dtype)
        write_idx = jnp.where(is_end, idx_s, scratch)
        padded = jnp.concatenate([table, table[:1]], axis=0)
        new_table = padded.at[write_idx].set(val_s)[:-1]
        return RmwResult(new_table, fetched_s[inv], jnp.ones((n,), bool))

    comb = _combine_fn(op)
    incl = segmented_scan(val_s, seg_start, comb)
    exc = _exclusive_from_inclusive(incl, val_s, seg_start,
                                    _identity(op, values.dtype))
    fetched_s = comb(base, exc) if op != "faa" else base + exc
    if op == "faa":
        new_table = table.at[indices].add(values)
    elif op == "min":
        new_table = table.at[indices].min(values)
    else:
        new_table = table.at[indices].max(values)
    return RmwResult(new_table, fetched_s[inv], jnp.ones((n,), bool))


def _cas_uniform(table: Array, indices: Array, values: Array,
                 expected: Array) -> RmwResult:
    """CAS with one shared expected value: first collider at a matching slot
    wins; later colliders observe the winner's value and fail (paper's BFS
    pattern: cas(parent[v], -1, u)).  1-D tables only."""
    exp_all = jnp.broadcast_to(jnp.asarray(expected, table.dtype), values.shape)
    order, inv, idx_s, seg_start, (val_s, exp_s) = _sort_by_index(
        indices, values, exp_all)
    base = table[idx_s]
    matches = base == exp_s  # slot held `expected` before the batch
    # Serialized chain semantics: ops succeed while the slot still holds
    # `expected`.  Writing desired == expected keeps the chain alive; the
    # first op writing desired != expected ("break op") ends it.
    eq = (val_s == exp_s).astype(jnp.int32)
    incl_alive = segmented_scan(eq, seg_start, jnp.minimum)
    alive_excl = _exclusive_from_inclusive(incl_alive, eq, seg_start, 1
                                           ).astype(bool)
    success_s = matches & alive_excl
    break_op = success_s & (eq == 0)
    contrib = jnp.where(break_op, val_s, jnp.zeros_like(val_s))
    incl_break = segmented_scan(contrib, seg_start, jnp.add)
    break_excl = _exclusive_from_inclusive(incl_break, contrib, seg_start, 0)
    fetched_s = jnp.where(alive_excl | ~matches, base, break_excl)
    # Table write: only the break op changes the slot's value.
    scratch = jnp.asarray(table.shape[0], idx_s.dtype)
    write_idx = jnp.where(break_op, idx_s, scratch)
    padded = jnp.concatenate([table, table[:1]], axis=0)
    new_table = padded.at[write_idx].set(val_s)[:-1]
    return RmwResult(new_table, fetched_s[inv], success_s[inv])


def scatter_add_grads(grad_table: Array, token_ids: Array,
                      grads: Array) -> Array:
    """Embedding-gradient accumulation = a pure-FAA RMW batch (dense archs'
    use of the paper technique; DESIGN.md §5)."""
    return grad_table.at[token_ids].add(grads)
