"""Cost-model-driven planning decisions (the paper's methodology as a feature).

The paper's §6.1 lesson: primitives cost the same, so *choose by semantics and
let the model price the alternatives*.  The planner applies that to the three
recurring choices the framework must make:

1. gradient-sync schedule per mesh axis (all-reduce vs ZeRO vs compressed),
2. FSDP gather dtype,
3. MoE dispatch capacity factor + drop semantics (SWP drop-newest vs
   CAS-priority keep-highest-gate), priced by the contention model.

Every decision returns the full priced table so EXPERIMENTS.md can show the
napkin math alongside the choice.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core import contention
from repro.core.collective_model import (MeshAxis, cross_pod_hierarchical,
                                         grad_sync_strategies)
from repro.core.perf_model import TPU_V5E, HardwareSpec
from repro.core.placement import Tier


@dataclass(frozen=True)
class PlanDecision:
    choice: str
    priced: Dict[str, float] = field(default_factory=dict)
    note: str = ""


def plan_grad_sync(grad_bytes: int, data_axis: MeshAxis,
                   pod_axis: Optional[MeshAxis] = None,
                   spec: HardwareSpec = TPU_V5E,
                   allow_compression: bool = True) -> PlanDecision:
    """Pick the gradient synchronization schedule for the data axis (+pods)."""
    table = grad_sync_strategies(spec, grad_bytes, data_axis)
    if pod_axis is not None and pod_axis.size > 1:
        table = {k: v + cross_pod_hierarchical(
            spec, grad_bytes if k == "all_reduce" else grad_bytes // 4
            if k == "zero_int8" else grad_bytes, data_axis, pod_axis)
            for k, v in table.items()}
    candidates = dict(table)
    if not allow_compression:
        candidates.pop("zero_int8", None)
    choice = min(candidates, key=candidates.get)
    note = ("ZeRO (RS+AG) also shards optimizer state 1/n — preferred on ties; "
            "int8 path uses error-feedback to bound bias.")
    if choice == "all_reduce" and abs(
            candidates["all_reduce"] - candidates.get("zero", float("inf"))) \
            / max(candidates["all_reduce"], 1e-30) < 0.05:
        choice = "zero"  # tie-break toward the memory win
    return PlanDecision(choice=choice, priced=table, note=note)


def plan_fsdp_gather_dtype(param_bytes_fp32: int, axis: MeshAxis,
                           spec: HardwareSpec = TPU_V5E) -> PlanDecision:
    """bf16 vs fp32 all-gather of FSDP-sharded params inside the layer scan."""
    from repro.core.collective_model import collective_time_s
    t32 = collective_time_s(spec, "all_gather", param_bytes_fp32, axis)
    t16 = collective_time_s(spec, "all_gather", param_bytes_fp32 // 2, axis)
    return PlanDecision(
        choice="bf16" if t16 < t32 else "fp32",
        priced={"fp32": t32, "bf16": t16},
        note="fp32 master weights stay sharded; bf16 copies are gathered.")


def plan_moe_dispatch(tokens_per_step: int, n_experts: int, top_k: int,
                      ep_degree: int, step_budget_s: float,
                      hot_fraction: float = 0.2,
                      spec: HardwareSpec = TPU_V5E) -> PlanDecision:
    """Capacity factor + overflow semantics from the contention model.

    The hot expert is the contended cache line (§5.4).  Capacity factor is
    sized so combining-mode dispatch absorbs the modeled hot load within the
    step budget; overflow semantics:
      * 'swp_drop_newest'  — overflowing tokens dropped (SWP: last loses),
      * 'cas_keep_top_gate'— overflow resolved by gate priority (CAS winner).
    The paper's finding that the primitives themselves cost the same means
    this is purely a semantics choice; we default to gate priority, which
    empirically (benchmarks/bfs.py analogue) loses less routed mass.
    """
    cap = contention.hot_expert_capacity(
        spec, tokens_per_step, n_experts, top_k, n_writers=ep_degree,
        hot_fraction=hot_fraction, step_budget_s=step_budget_s)
    cap = float(min(max(1.0, cap), 4.0))  # clamp to sane dispatch-buffer sizes
    bw_ser = contention.contended_bandwidth_serialized(spec, "faa", ep_degree)
    bw_comb = contention.contended_bandwidth_combining(spec, "faa", ep_degree)
    return PlanDecision(
        choice=f"capacity_factor={cap:.2f};overflow=cas_keep_top_gate",
        priced={"contended_serialized_Bps": bw_ser,
                "contended_combining_Bps": bw_comb,
                "capacity_factor": cap},
        note="combining-tree dispatch (paper §6.2.3 fix); overflow by gate "
             "priority (CAS semantics) rather than arrival order (SWP).")


def default_axes(mesh_shape: Dict[str, int]) -> Dict[str, MeshAxis]:
    """Name->MeshAxis helper matching launch/mesh.py conventions."""
    tiers = {"data": Tier.ICI_NEIGHBOR, "model": Tier.ICI_NEIGHBOR,
             "pod": Tier.DCN_REMOTE_POD}
    return {name: MeshAxis(name=name, size=size, tier=tiers.get(
        name, Tier.ICI_NEIGHBOR)) for name, size in mesh_shape.items()}
