"""Contention model — paper §5.4 (Fig. 8a-c) adapted to TPU shards.

The paper measures n threads hammering one cache line: the line ping-pongs
between owners, so aggregate atomic bandwidth *collapses* instead of scaling.
The TPU analogue is n writers (cores or chips) combining into one table shard
(e.g. a hot MoE expert or a shared counter).

Two regimes are modeled:

* ``serialized``  — ownership ping-pong, the paper's measured hardware
  behaviour: each op must re-acquire the line from the previous owner
  (always a remote placement once n > 1).
* ``combining``   — a reduction tree (the software fix TPUs can apply, and
  the hardware fix the paper proposes in §6.2): writers pre-combine locally,
  then reduce up a log2(n) tree.

The crossover between the two is what the MoE capacity planner consumes.
"""

from __future__ import annotations

import math

from repro.core.perf_model import HardwareSpec, latency, read_for_ownership
from repro.core.placement import Ownership, PlacementState, Tier


def contended_bandwidth_serialized(spec: HardwareSpec, op: str, n_writers: int,
                                   remote_tier: Tier = Tier.ICI_NEIGHBOR,
                                   operand_bytes: int = 8) -> float:
    """Aggregate bytes/s of n writers RMW-ing one shard, ping-pong regime.

    n == 1: the owner hits its local tier at full serialized-atomic rate.
    n >= 2: every op's read-for-ownership targets the previous owner's cache —
    a remote placement in the S state with n replicas wanting the line.  The
    whole system completes one op per L(A, S_remote): aggregate bandwidth is
    *independent of n* (and far below n * single-writer) — the paper's Fig. 8
    plateau.  A mild sqrt(n) queueing penalty models the arbitration the
    paper observed on Xeon Phi/Bulldozer before the plateau.
    """
    if n_writers <= 1:
        local = PlacementState(tier=Tier.VMEM)
        return operand_bytes / latency(spec, op, local, operand_bytes)
    state = PlacementState(tier=remote_tier, ownership=Ownership.SHARED,
                           n_replicas=max(2, n_writers))
    l = latency(spec, op, state, operand_bytes)
    queue = 1.0 + 0.1 * math.sqrt(n_writers)
    return operand_bytes / (l * queue)


def contended_bandwidth_combining(spec: HardwareSpec, op: str, n_writers: int,
                                  remote_tier: Tier = Tier.ICI_NEIGHBOR,
                                  operand_bytes: int = 8,
                                  batch_per_writer: int = 1024) -> float:
    """Aggregate bytes/s under combining-tree reduction (the fix).

    Each writer locally pre-combines ``batch_per_writer`` operands (free ILP),
    then a binary reduction tree of depth ceil(log2 n) moves one combined
    operand per level.  Aggregate useful bandwidth grows ~linearly in n until
    the tree root's tier bandwidth saturates.
    """
    useful = n_writers * batch_per_writer * operand_bytes
    local_combine = batch_per_writer / spec.combine_ops_per_s
    depth = math.ceil(math.log2(max(2, n_writers)))
    hop = read_for_ownership(spec, PlacementState(tier=remote_tier), operand_bytes)
    t = local_combine + depth * (hop + spec.execute_s.get(op, 0.0))
    root_cap = spec.tier_bandwidth_Bps[remote_tier]
    return min(useful / t, root_cap * n_writers)


def contended_bandwidth_hierarchical(spec: HardwareSpec, op: str,
                                     n_pods: int, writers_per_pod: int,
                                     ici_tier: Tier = Tier.ICI_NEIGHBOR,
                                     dcn_tier: Tier = Tier.DCN_REMOTE_POD,
                                     operand_bytes: int = 8,
                                     batch_per_writer: int = 1024) -> float:
    """Aggregate bytes/s under *two-level* combining: per-pod ICI tree, then
    one cross-pod DCN reduction (the paper's §6.2 combining tree spanning
    pods; `core/rmw_sharded.py` is the executable realization).

    Relative to the flat tree of :func:`contended_bandwidth_combining` over
    all ``n_pods * writers_per_pod`` writers, the hierarchy pays the slow DCN
    hop only ``ceil(log2 n_pods)`` times instead of on every upper tree
    level — the crossover in favour of hierarchy grows with the DCN:ICI
    latency ratio and with per-pod writer count.  Includes the per-collective
    software launch (`HardwareSpec.collective_launch_s`), which is what keeps
    one-shot ahead for tiny uncontended batches.
    """
    n_writers = n_pods * writers_per_pod
    useful = n_writers * batch_per_writer * operand_bytes
    local_combine = batch_per_writer / max(spec.combine_ops_per_s, 1.0)
    ici_depth = math.ceil(math.log2(max(2, writers_per_pod)))
    dcn_depth = math.ceil(math.log2(max(2, n_pods))) if n_pods > 1 else 0
    ici_hop = read_for_ownership(spec, PlacementState(tier=ici_tier),
                                 operand_bytes)
    dcn_hop = read_for_ownership(spec, PlacementState(tier=dcn_tier),
                                 operand_bytes)
    e = spec.execute_s.get(op, 0.0)
    t = (local_combine + ici_depth * (ici_hop + e) + dcn_depth * (dcn_hop + e)
         + 2 * spec.collective_launch_s)
    root_cap = spec.tier_bandwidth_Bps[dcn_tier if n_pods > 1 else ici_tier]
    return min(useful / t, root_cap * n_writers)


def hierarchical_crossover_pods(spec: HardwareSpec, op: str,
                                writers_per_pod: int, max_pods: int = 64,
                                ici_tier: Tier = Tier.ICI_NEIGHBOR,
                                dcn_tier: Tier = Tier.DCN_REMOTE_POD,
                                operand_bytes: int = 8,
                                batch_per_writer: int = 1024) -> int:
    """Smallest pod count at which two-level combining beats the flat tree
    (paper Fig. 8 crossover, distributed edition); 0 if it never does.
    Both trees see the same tiers: the flat tree's every upper level rides
    the cross-pod `dcn_tier`."""
    for n_pods in range(2, max_pods + 1):
        flat = contended_bandwidth_combining(
            spec, op, n_pods * writers_per_pod, remote_tier=dcn_tier,
            operand_bytes=operand_bytes, batch_per_writer=batch_per_writer)
        hier = contended_bandwidth_hierarchical(
            spec, op, n_pods, writers_per_pod, ici_tier=ici_tier,
            dcn_tier=dcn_tier, operand_bytes=operand_bytes,
            batch_per_writer=batch_per_writer)
        if hier > flat:
            return n_pods
    return 0


def hot_expert_capacity(spec: HardwareSpec, tokens_per_step: int, n_experts: int,
                        top_k: int, n_writers: int,
                        hot_fraction: float = 0.2,
                        step_budget_s: float | None = None) -> float:
    """Capacity-factor suggestion from the contention model.

    A hot expert receiving ``hot_fraction`` of all routed tokens is the
    contended cache line.  We size the per-expert capacity so the combining
    regime (which the framework uses) keeps the dispatch within the step
    budget; returns the capacity factor (>= 1.0 means headroom).

    This realizes the paper's §6.1 message: choose the *semantics* (drop
    policy) from the model, because the primitive costs are equal.
    """
    assignments = tokens_per_step * top_k
    mean_per_expert = assignments / n_experts
    hot_load = hot_fraction * assignments
    bw = contended_bandwidth_combining(spec, "faa", n_writers)
    # time to absorb the hot expert's updates (8B routing record per token)
    t_hot = hot_load * 8 / bw
    if step_budget_s is None:
        step_budget_s = max(t_hot, 1e-9)
    # capacity factor that bounds dispatch time to the budget
    sustainable = bw * step_budget_s / 8
    return max(1.0, min(hot_load, sustainable) / max(mean_per_expert, 1.0))
