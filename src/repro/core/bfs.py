"""Graph500-style BFS with selectable RMW combiner semantics (paper §6.1).

The paper's point: CAS/SWP/FAA cost the same, so pick the primitive whose
*semantics* fit — for the bfs_tree parent array, CAS (set-if-unvisited) and
SWP (swap + revert) give simple protocols while FAA needs a revert scheme.
We reproduce the comparison with the vectorized combining RMW: per BFS
level, all frontier edges issue parent-updates through the chosen typed op
(`repro.atomics.execute`) — the cost-model auto-selected backend by default
(typically the sort-free one-hot backend for frontier-sized batches),
overridable per run for benchmarking.  The sharded variant runs the same
ops against an `AtomicTable` sharded over the mesh axis; `execute` detects
the shard_map context and routes through the exchange strategies.

Kronecker (RMAT) generator included — the paper benchmarks on Kronecker
graphs that model heavy-tailed real-world graphs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import atomics

Array = jax.Array


def kronecker_graph(scale: int, edgefactor: int = 8, seed: int = 0,
                    a=0.57, b=0.19, c=0.19) -> Tuple[np.ndarray, np.ndarray]:
    """RMAT edge list (Graph500 generator), n = 2**scale nodes."""
    n_edges = edgefactor * (1 << scale)
    rng = np.random.default_rng(seed)
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    for level in range(scale):
        r = rng.random(n_edges)
        bit_src = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        r2 = rng.random(n_edges)
        bit_dst = ((r < a + b) & (r >= a)) | (r >= a + b + c)
        del r2
        src |= bit_src.astype(np.int64) << level
        dst |= bit_dst.astype(np.int64) << level
    perm = rng.permutation(1 << scale)       # shuffle vertex labels
    return perm[src], perm[dst]


@dataclasses.dataclass
class BfsResult:
    parent: Array
    levels: int
    edges_traversed: int


@partial(jax.jit, static_argnames=("n", "op", "max_levels", "backend"))
def _bfs_run(src: Array, dst: Array, root, n: int, op: str,
             max_levels: int = 64, backend: str = "auto"):
    parent = jnp.full((n,), -1, jnp.int32).at[root].set(root)

    def level(state):
        parent, frontier, lvl, edges = state
        active = frontier[src]                       # edge's src in frontier
        cand_dst = jnp.where(active, dst, n)         # OOR -> dropped
        cand_par = src.astype(jnp.int32)
        if op == "cas":
            res = atomics.execute(
                parent, atomics.Cas(cand_dst, cand_par, expected=-1),
                backend=backend, need_fetched=False)
            new_parent = res.table.data
        elif op == "swp":
            # swap unconditionally, then revert overwrites of visited nodes.
            # The restore value is the FIRST collider's fetched (the original
            # parent), so the revert stream runs reversed (last-wins of the
            # reversed order == first in program order).
            res = atomics.execute(parent, atomics.Swp(cand_dst, cand_par),
                                  backend=backend)
            visited_before = res.fetched != -1
            revert_idx = jnp.where(visited_before, cand_dst, n)
            new_parent = atomics.execute(
                res.table, atomics.Swp(revert_idx[::-1], res.fetched[::-1]),
                backend=backend, need_fetched=False).table.data
        else:  # faa with revert (the paper's "complex scheme")
            delta = jnp.where(parent[jnp.clip(cand_dst, 0, n - 1)] == -1,
                              cand_par + 1, 0)
            res = atomics.execute(parent, atomics.Faa(cand_dst, delta),
                                  backend=backend, need_fetched=False)
            over = res.table.data  # -1 + sum(deltas); keep 1st contributor
            # revert: recompute exact winner via min-combine of parities
            first = atomics.execute(
                jnp.full((n,), jnp.iinfo(jnp.int32).max, jnp.int32),
                atomics.Min(cand_dst,
                            jnp.where(delta > 0, cand_par,
                                      jnp.iinfo(jnp.int32).max)),
                backend=backend, need_fetched=False).table.data
            new_parent = jnp.where(
                (parent == -1) & (first != jnp.iinfo(jnp.int32).max),
                first, parent)
            del over
        new_frontier = (new_parent != -1) & (parent == -1)
        edges = edges + jnp.sum(active)
        return new_parent, new_frontier, lvl + 1, edges

    def cond(state):
        _, frontier, lvl, _ = state
        return jnp.any(frontier) & (lvl < max_levels)

    frontier0 = jnp.zeros((n,), bool).at[root].set(True)
    parent, _, lvl, edges = jax.lax.while_loop(
        cond, level, (parent, frontier0, jnp.int32(0), jnp.int32(0)))
    return parent, lvl, edges


def bfs(src: np.ndarray, dst: np.ndarray, n: int, root: int = 0,
        op: str = "cas", backend: str = "auto") -> BfsResult:
    """Level-synchronous BFS; op ∈ {cas, swp, faa} picks the combiner and
    ``backend`` the RMW engine implementation ("auto" = cost-model pick)."""
    parent, lvl, edges = _bfs_run(
        jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
        jnp.int32(root), int(n), op, backend=backend)
    return BfsResult(parent=parent, levels=int(lvl),
                     edges_traversed=int(edges))


def bfs_sharded(src: np.ndarray, dst: np.ndarray, n: int, root: int = 0,
                *, axis: str = "dev", mesh=None, strategy: str = "auto",
                op: str = "cas", max_levels: int = 64) -> BfsResult:
    """Level-synchronous BFS with the **frontier table sharded over a mesh**.

    The parent array — the paper's contended cache line — is sharded over
    `axis` (vertex ``v`` owned by shard ``v // n_local``); edges are split
    over the same devices.  Each level gathers the frontier bitmap and issues
    every frontier edge's parent update through the sharded tier of
    `repro.atomics.execute`.  Parent selection is identical to the
    single-device `bfs` because the arrival-order contract serializes edges
    in (device-rank, local) order — exactly the concatenated edge order of
    the unsharded run.

    ``op`` picks the combiner protocol, mirroring `bfs`:

    ``"cas"``  set-if-unvisited (`Cas(dst, src, expected=-1)`): per-device
               pre-combine (one CAS per distinct destination survives),
               owner-shard resolve, table-only fast path.
    ``"swp"``  swap + revert: pass 1 swaps unconditionally and fetches the
               overwritten parents; pass 2 restores already-visited nodes
               by replaying the revert stream **globally reversed** —
               locally reversed batches under ``reverse_ranks=True``
               (descending device rank), so last-wins of the reversed
               stream equals first-wins of the forward stream, exactly
               the single-device scheme.
    """
    if op not in ("cas", "swp"):
        raise ValueError(f"bfs_sharded supports op 'cas' or 'swp', "
                         f"got {op!r}")
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), (axis,))
    ndev = int(mesh.shape[axis])
    n_pad = -(-n // ndev) * ndev
    e_pad = -(-len(src) // ndev) * ndev
    srcp = np.full((e_pad,), n_pad, np.int32)
    dstp = np.full((e_pad,), n_pad, np.int32)
    srcp[:len(src)] = np.asarray(src, np.int32)
    dstp[:len(dst)] = np.asarray(dst, np.int32)
    parent0 = jnp.full((n_pad,), -1, jnp.int32).at[root].set(root)
    frontier0 = jnp.zeros((n_pad,), bool).at[root].set(True)
    P = jax.sharding.PartitionSpec

    def shard_fn(parent, frontier, s, d):
        def body(state):
            parent, frontier, lvl, edges, _ = state
            fg = jax.lax.all_gather(frontier, axis, tiled=True)  # (n_pad,)
            active = fg[jnp.clip(s, 0, n_pad - 1)] & (s < n_pad)
            cand = jnp.where(active, d, n_pad)                   # OOR drops
            tbl = atomics.AtomicTable(parent, axis=axis)
            if op == "cas":
                res = atomics.execute(
                    tbl, atomics.Cas(cand, s, expected=jnp.int32(-1)),
                    strategy=strategy, need_fetched=False)
                new_parent = res.table.data
            else:  # swp + revert (see docstring)
                res = atomics.execute(tbl, atomics.Swp(cand, s),
                                      strategy=strategy)
                visited_before = res.fetched != -1
                revert_idx = jnp.where(visited_before, cand, n_pad)
                new_parent = atomics.execute(
                    res.table,
                    atomics.Swp(revert_idx[::-1], res.fetched[::-1]),
                    strategy=strategy, need_fetched=False,
                    reverse_ranks=True).table.data
            newf = (new_parent != -1) & (parent == -1)
            edges = edges + jax.lax.psum(jnp.sum(active), axis)
            more = jax.lax.psum(jnp.sum(newf), axis) > 0
            return new_parent, newf, lvl + jnp.int32(1), edges, more
        def cond(state):
            _, _, lvl, _, more = state
            return more & (lvl < max_levels)
        parent, _, lvl, edges, _ = jax.lax.while_loop(
            cond, body, (parent, frontier, jnp.int32(0), jnp.int32(0),
                         jnp.array(True)))
        return parent, lvl[None], edges[None]

    from repro.sharding import shard_map_compat
    mapped = shard_map_compat(shard_fn, mesh,
                              (P(axis), P(axis), P(axis), P(axis)),
                              (P(axis), P(axis), P(axis)))
    parent, lvl, edges = jax.jit(mapped)(parent0, frontier0,
                                         jnp.asarray(srcp), jnp.asarray(dstp))
    return BfsResult(parent=parent[:n], levels=int(lvl[0]),
                     edges_traversed=int(edges[0]))


def validate_parents(src: np.ndarray, dst: np.ndarray, parent: np.ndarray,
                     root: int) -> bool:
    """Every reached vertex's parent edge must exist; root is its own parent."""
    parent = np.asarray(parent)
    if parent[root] != root:
        return False
    edges = set(zip(np.asarray(src).tolist(), np.asarray(dst).tolist()))
    for v in np.nonzero(parent >= 0)[0]:
        if v == root:
            continue
        if (int(parent[v]), int(v)) not in edges:
            return False
    return True
