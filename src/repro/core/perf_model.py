"""The paper's three-term performance model, adapted to TPU tiers.

Paper (Eq. 1):          L(A, S) = R_O(S) + E(A) + O
Bandwidth (Eq. 9):      B(A, S) = C_size / L(A, S)
Amortized bw (Eq. 10):  first access to a line pays L, subsequent N-1 operand
                        accesses within the line pay (R_L1 + E(A)) each.

Adaptation (see DESIGN.md §2): the cache line becomes a VMEM tile, the
coherency state S becomes a :class:`~repro.core.placement.PlacementState`
(tier × ownership × replica count), and the constants are held in a
:class:`HardwareSpec` — one analytically specified for the TPU v5e target and
one calibrated at runtime on the container's CPU by the benchmark harness
(mirroring the paper's per-architecture Table 2).

All latencies are in **seconds**, sizes in **bytes**, bandwidths in **bytes/s**.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, Mapping, Tuple

from repro.core.placement import Ownership, PlacementState, Tier

# ---------------------------------------------------------------------------
# RMW operation kinds (the paper's atomics)
# ---------------------------------------------------------------------------

#: Paper ops.  ``CAS2`` is the two-operands-fetched CAS variant of §5.5.
RMW_OPS = ("cas", "faa", "swp", "cas2", "read", "write")


@dataclass(frozen=True)
class HardwareSpec:
    """Constants of one architecture (the paper's Table 1 + Table 2 merged)."""

    name: str
    # Latency of fetching one tile ("cache line") with the authoritative copy
    # in each tier — the paper's R_{L1,l}, R_{L2,l}, R_{L3,l}, H, M.
    tier_latency_s: Mapping[Tier, float] = field(default_factory=dict)
    # Streaming bandwidth of each tier (for the size-dependent part of R_O).
    tier_bandwidth_Bps: Mapping[Tier, float] = field(default_factory=dict)
    # E(A): execute latency of each RMW op (paper Table 2 E rows).
    execute_s: Mapping[str, float] = field(default_factory=dict)
    # O: calibrated residual per (op, tier) — the paper's Table 3.
    residual_s: Mapping[Tuple[str, Tier], float] = field(default_factory=dict)
    # Tile ("cache line") geometry.
    tile_bytes: int = 8 * 128 * 4            # one fp32 VMEM tile (8 sublanes x 128 lanes)
    # Per-hop ICI latency for multi-hop placements (paper: H per die-die hop).
    ici_hop_s: float = 0.0
    # Peak compute + HBM bandwidth for roofline use.
    peak_flops: float = 0.0
    hbm_Bps: float = 0.0
    ici_link_Bps: float = 0.0
    # Relaxed/combining-mode per-element throughput (ops/s) — the ILP ceiling.
    combine_ops_per_s: float = 0.0
    # --- RMW-engine backend-selection constants (core/rmw_engine.py) ---
    # Per-element cost of ONE pass of a hardware sort network/merge phase;
    # the argsort backend pays ~log2(n) of these.  0 -> derived fallback.
    sort_elem_pass_s: float = 0.0
    # Amortized per-element random gather/scatter cost against a table that
    # fits the working tier (vectorized, pipelined — NOT a full miss).
    gather_elem_s: float = 0.0
    # Per-block loop-step overhead of the blocked one-hot backend (scan/DMA
    # bookkeeping per (batch-block) iteration).
    loop_step_s: float = 0.0
    # --- distributed-exchange terms (core/rmw_sharded.py, contention.py) ---
    # Per-link DCN bandwidth for cross-pod exchanges (the ICI analogue is
    # `ici_link_Bps`); tier_bandwidth_Bps[DCN_REMOTE_POD] stays the raw
    # streaming number while this is the per-collective effective rate.
    dcn_link_Bps: float = 0.0
    # Software dispatch cost of launching ONE collective (all_to_all /
    # psum_scatter ring setup) — dominates small contended exchanges and is
    # what makes hierarchical (3 collectives) lose to one-shot (2) on
    # uncontended batches.
    collective_launch_s: float = 0.0
    # --- migration terms (repro.atomics.reshard: elastic table moves) ---
    # Effective device<->host bandwidth of a full-table gather/scatter (the
    # host-roundtrip migration path); 0 -> tier_bandwidth_Bps[HOST].
    host_roundtrip_Bps: float = 0.0
    # Dispatch cost of one host->devices placement (device_put of a sharded
    # table) — the latency floor of the host-roundtrip path, what the
    # in-collective exchange path avoids.
    device_put_launch_s: float = 0.0

    def with_residuals(self, residual: Mapping[Tuple[str, Tier], float]) -> "HardwareSpec":
        return replace(self, residual_s=dict(residual))


# ---------------------------------------------------------------------------
# TPU v5e target constants (the modeled half; DESIGN.md §8 item 4)
# ---------------------------------------------------------------------------

_US = 1e-6
_NS = 1e-9

TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    tier_latency_s={
        Tier.VREG: 1 * _NS,            # register-file access
        Tier.VMEM: 20 * _NS,           # VMEM load-use
        Tier.HBM_LOCAL: 650 * _NS,     # HBM->VMEM DMA latency (small transfer)
        Tier.ICI_NEIGHBOR: 1.5 * _US,  # 1 ICI hop
        Tier.ICI_FAR: 1.5 * _US,       # per-hop; multiplied by `hops`
        Tier.DCN_REMOTE_POD: 50 * _US, # DCN round
        Tier.HOST: 5 * _US,            # PCIe
    },
    tier_bandwidth_Bps={
        Tier.VREG: 4e13,
        Tier.VMEM: 8e12,
        Tier.HBM_LOCAL: 819e9,
        Tier.ICI_NEIGHBOR: 50e9,
        Tier.ICI_FAR: 50e9,
        Tier.DCN_REMOTE_POD: 25e9,
        Tier.HOST: 16e9,
    },
    execute_s={"cas": 8 * _NS, "cas2": 10 * _NS, "faa": 6 * _NS, "swp": 6 * _NS,
               "read": 0.0, "write": 2 * _NS},
    ici_hop_s=1.5 * _US,
    peak_flops=197e12,
    hbm_Bps=819e9,
    ici_link_Bps=50e9,
    combine_ops_per_s=197e12 / 2,      # VPU-bound elementwise combine ceiling
    # TPUs sort badly (no sort network; lowered to O(log^2 n) bitonic passes
    # over the VPU) while one-hot contractions hit the MXU: bias accordingly.
    sort_elem_pass_s=4e-9,
    gather_elem_s=2e-9,
    loop_step_s=2e-6,
    dcn_link_Bps=25e9,
    collective_launch_s=1e-6,
    host_roundtrip_Bps=16e9,           # PCIe-bound full-table roundtrip
    device_put_launch_s=5e-6,
)


def cpu_default_spec() -> HardwareSpec:
    """Uncalibrated CPU spec (order-of-magnitude priors; benchmarks calibrate it)."""
    return HardwareSpec(
        name="cpu_host",
        tier_latency_s={
            Tier.VREG: 0.3 * _NS,
            Tier.VMEM: 1.2 * _NS,      # L1/L2 in the CPU mapping
            Tier.HBM_LOCAL: 80 * _NS,  # DRAM
            Tier.ICI_NEIGHBOR: 100 * _NS,
            Tier.ICI_FAR: 100 * _NS,
            Tier.DCN_REMOTE_POD: 50 * _US,
            Tier.HOST: 80 * _NS,
        },
        tier_bandwidth_Bps={
            Tier.VREG: 1e12,
            Tier.VMEM: 4e11,
            Tier.HBM_LOCAL: 2e10,
            Tier.ICI_NEIGHBOR: 1e10,
            Tier.ICI_FAR: 1e10,
            Tier.DCN_REMOTE_POD: 1e9,
            Tier.HOST: 2e10,
        },
        execute_s={"cas": 5 * _NS, "cas2": 7 * _NS, "faa": 5 * _NS, "swp": 5 * _NS,
                   "read": 0.0, "write": 1 * _NS},
        tile_bytes=64,                 # the CPU's actual cache line
        ici_hop_s=100 * _NS,
        peak_flops=5e10,
        hbm_Bps=2e10,
        ici_link_Bps=1e10,
        combine_ops_per_s=2e9,
        # XLA:CPU's stable sort costs ~O(n log n) comparator work; gathers
        # are cheap while they hit cache.  Tuned against the committed
        # benchmarks/results/rmw_backends.json table for this container.
        sort_elem_pass_s=3e-9,
        gather_elem_s=1.5e-9,
        loop_step_s=1.5e-6,
        # fake-device "pods" on one host still pay XLA's collective dispatch
        dcn_link_Bps=1e9,
        collective_launch_s=2e-5,
        # host "roundtrip" on CPU devices is a memcpy, but each sharded
        # device_put pays Python/XLA placement dispatch per buffer
        host_roundtrip_Bps=1e10,
        device_put_launch_s=2e-4,
    )


# ---------------------------------------------------------------------------
# The model proper
# ---------------------------------------------------------------------------

def read_latency(spec: HardwareSpec, state: PlacementState,
                 nbytes: int | None = None) -> float:
    """R(S): plain-read latency of a tile whose authoritative copy is at S.tier.

    Implements the paper's Eq. (3)–(6) ladder: local-tier latency, plus hop
    penalties for remote tiers (H per hop, Eq. (6)/§4.1.3), plus a streaming
    term for payloads larger than the latency-dominated minimum.
    """
    nbytes = spec.tile_bytes if nbytes is None else nbytes
    base = spec.tier_latency_s[state.tier]
    if state.tier is Tier.ICI_FAR:
        base += spec.ici_hop_s * (state.hops - 1)
    stream = nbytes / spec.tier_bandwidth_Bps[state.tier]
    return base + stream


def read_for_ownership(spec: HardwareSpec, state: PlacementState,
                       nbytes: int | None = None) -> float:
    """R_O(S): acquire an exclusive copy, invalidating any replicas.

    EXCLUSIVE (paper E/M, Eq. (2)):  R_O = R(S).
    SHARED    (paper S/O, Eq. (8)):  R_O = R(E) + max_i R_i(E) — invalidations
    proceed in parallel, so one extra replica round-trip dominates regardless
    of replica count; a log2 fan-out term models multicast tree depth on the
    torus (replica count enters only logarithmically, consistent with the
    paper's observation that S-state latency is roughly replica-independent).
    """
    r = read_latency(spec, state, nbytes)
    if state.ownership is Ownership.EXCLUSIVE:
        return r
    inv = read_latency(spec, PlacementState(tier=state.tier, hops=state.hops), nbytes)
    fanout = math.log2(max(2, state.n_replicas))
    return r + inv * (1.0 + 0.1 * (fanout - 1.0))


def latency(spec: HardwareSpec, op: str, state: PlacementState,
            nbytes: int | None = None) -> float:
    """L(A, S) = R_O(S) + E(A) + O   (paper Eq. (1)).

    ``read`` does not acquire ownership; all RMW ops do (the paper found that
    even failing CAS issues the read-for-ownership — §5.1.1 last paragraph —
    so we model every RMW identically on that axis).
    """
    if op not in RMW_OPS:
        raise ValueError(f"unknown op {op!r}; expected one of {RMW_OPS}")
    if op == "read":
        acquire = read_latency(spec, state, nbytes)
    else:
        acquire = read_for_ownership(spec, state, nbytes)
    if op == "cas2":  # two operands fetched (§5.5): second fetch pipelines,
        # costing only a pipelined local read, not a full round (paper: +2-4ns
        # local, +15-30ns remote).
        acquire += 0.25 * read_latency(spec, state, nbytes)
    execute = spec.execute_s.get(op, 0.0)
    o = spec.residual_s.get((op, state.tier), 0.0)
    return acquire + execute + o


def bandwidth(spec: HardwareSpec, op: str, state: PlacementState,
              operand_bytes: int = 8) -> float:
    """Serialized-atomics bandwidth, paper Eq. (9)/(10).

    Every tile ("cache line") load pays L(A,S); the remaining N-1 operands in
    the tile each pay a VREG-tier access plus E(A) — atomics are serialized
    (no ILP), the paper's insight I2.  Returns useful bytes/s.
    """
    n = max(1, spec.tile_bytes // operand_bytes)
    l_first = latency(spec, op, state)
    per_op = read_latency(spec, PlacementState(tier=Tier.VREG), operand_bytes) \
        + spec.execute_s.get(op, 0.0)
    total = l_first + (n - 1) * per_op
    return spec.tile_bytes / total


def relaxed_bandwidth(spec: HardwareSpec, state: PlacementState,
                      operand_bytes: int = 8) -> float:
    """Combining-mode bandwidth — the paper's proposed relaxed atomics (§6.2.3).

    Independent RMWs pipeline: throughput is min(tier streaming bandwidth,
    combine ALU ceiling).  The ratio relaxed/serialized reproduces the paper's
    5-30x atomics-vs-writes gap.
    """
    alu = spec.combine_ops_per_s * operand_bytes
    return min(spec.tier_bandwidth_Bps[state.tier], alu)


def ilp_gap(spec: HardwareSpec, op: str, state: PlacementState,
            operand_bytes: int = 8) -> float:
    """Modeled ratio of relaxed (write-like) to serialized (atomic) bandwidth."""
    return relaxed_bandwidth(spec, state, operand_bytes) / \
        bandwidth(spec, op, state, operand_bytes)


def unaligned_latency(spec: HardwareSpec, op: str, state: PlacementState) -> float:
    """Tile-spanning RMW (paper §5.7): both tiles must be owned atomically.

    The paper saw CAS jump to ~750ns — bus-lock semantics.  The TPU analogue
    of a tile-spanning combine is two dependent tile acquisitions plus a
    serialization penalty; we model L_unaligned = 2 L(A,S) + E(A).
    """
    return 2.0 * latency(spec, op, state) + spec.execute_s.get(op, 0.0)


# ---------------------------------------------------------------------------
# Persistence (benchmarks/calibrate.py writes, rmw_engine.default_spec loads)
# ---------------------------------------------------------------------------

def spec_to_dict(spec: HardwareSpec) -> Dict:
    """JSON-safe dict: Tier enums become their string values, residual keys
    become ``"op/tier"`` strings.  Inverse of :func:`spec_from_dict`."""
    import dataclasses
    d = dataclasses.asdict(spec)
    d["tier_latency_s"] = {t.value: v for t, v in spec.tier_latency_s.items()}
    d["tier_bandwidth_Bps"] = {t.value: v
                               for t, v in spec.tier_bandwidth_Bps.items()}
    d["residual_s"] = {f"{op}/{t.value}": v
                       for (op, t), v in spec.residual_s.items()}
    return d


def spec_from_dict(d: Mapping, base: HardwareSpec | None = None) -> HardwareSpec:
    """Rebuild a spec from :func:`spec_to_dict` output.  Unknown keys are
    ignored and missing ones inherit from ``base`` (so older calibration
    files keep working as the spec grows fields)."""
    base = base if base is not None else cpu_default_spec()
    by_value = {t.value: t for t in Tier}
    kw: Dict = {}
    for f in HardwareSpec.__dataclass_fields__:
        if f in d:
            kw[f] = d[f]
    if "tier_latency_s" in d:
        kw["tier_latency_s"] = {by_value[k]: float(v)
                                for k, v in d["tier_latency_s"].items()
                                if k in by_value}
    if "tier_bandwidth_Bps" in d:
        kw["tier_bandwidth_Bps"] = {by_value[k]: float(v)
                                    for k, v in d["tier_bandwidth_Bps"].items()
                                    if k in by_value}
    if "residual_s" in d:
        res = {}
        for k, v in d["residual_s"].items():
            op, _, tier = k.partition("/")
            if tier in by_value:
                res[(op, by_value[tier])] = float(v)
        kw["residual_s"] = res
    # tiers the file doesn't mention inherit the base spec's constants
    for field_name in ("tier_latency_s", "tier_bandwidth_Bps"):
        if field_name in kw:
            merged = dict(getattr(base, field_name))
            merged.update(kw[field_name])
            kw[field_name] = merged
    return replace(base, **kw)


# ---------------------------------------------------------------------------
# Calibration (the paper's §5 methodology: medians -> Table 2, residuals -> O)
# ---------------------------------------------------------------------------

def calibrate(spec: HardwareSpec,
              read_samples: Mapping[Tier, Iterable[float]],
              rmw_samples: Mapping[Tuple[str, Tier], Iterable[float]],
              ) -> HardwareSpec:
    """Fit tier latencies, execute costs, and residuals from measurements.

    Mirrors the paper exactly: tier latencies = median of read benchmarks
    (Table 2 R rows); E(A) = median over tiers of (L_measured - R); O =
    per-(op, tier) leftover (Table 3).
    """
    tier_lat = dict(spec.tier_latency_s)
    for tier, samples in read_samples.items():
        s = sorted(samples)
        if s:
            tier_lat[tier] = s[len(s) // 2]

    fitted = replace(spec, tier_latency_s=tier_lat)

    # E(A): median over (op, tier) of measured minus modeled acquisition.
    diffs: Dict[str, list] = {}
    medians: Dict[Tuple[str, Tier], float] = {}
    for (op, tier), samples in rmw_samples.items():
        s = sorted(samples)
        if not s:
            continue
        med = s[len(s) // 2]
        medians[(op, tier)] = med
        st = PlacementState(tier=tier)
        diffs.setdefault(op, []).append(med - read_for_ownership(fitted, st))
    execute = dict(spec.execute_s)
    for op, ds in diffs.items():
        ds = sorted(ds)
        execute[op] = max(0.0, ds[len(ds) // 2])
    fitted = replace(fitted, execute_s=execute)

    # O: residual per (op, tier) after the two fitted terms.
    residual: Dict[Tuple[str, Tier], float] = {}
    for (op, tier), med in medians.items():
        st = PlacementState(tier=tier)
        residual[(op, tier)] = med - (read_for_ownership(fitted, st)
                                      + execute.get(op, 0.0))
    return fitted.with_residuals(residual)
